"""Offline plan-cache sweep: warm every shipped GEMM instance before serving.

"Hello SME!" (PAPERS.md) shows kernel-search at *deployment* time paying
off at serve time; this module is that step for the plan cache.  It
enumerates every (model config × precision policy × operand layout ×
fused epilogue) GEMM instance the serving stack launches — the exact
cache keys ``mp_dot`` / ``mpgemm_pallas_spec`` look up — runs the modeled
(or compiled, on TPU) tuning sweep for each, and persists the winners
into a :class:`~repro.tuning.plan_cache.PlanCache`.  A serve process
pointed at the resulting file (``REPRO_PLAN_CACHE=<path>``) never plans a
shipped GEMM cold.

Instance derivation mirrors the model code (``models/blocks.py`` /
``models/layers.py``): attention projections, the (fused-epilogue) MLP
trio, MoE router + grouped expert GEMMs at capacity-factor token counts,
recurrent mixing mats, and the logits head.  Layouts cover dense and
packed B (the packed namespace key reuses ``pack_params``'s block
derivation so the tag matches what load-time packing will produce);
tile-sparse layouts are content-addressed by the weight's pruning
pattern, so they cannot be warmed without the checkpoint and are tuned
at sparsify time instead (``tune_sparse_gemm``).

CLI::

    PYTHONPATH=src python -m repro.perf.sweep --out plans.json \
        --archs granite-moe-1b-a400m --m-tokens 32 4096
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.configs import base as cb
from repro.core.blocking import (
    enumerate_block_lattice, grouped_plan_from_2d, plan_gemm,
    plan_with_blocks,
)
from repro.core.codecs import get_codec
from repro.core.constants import DEFAULT_HW, HardwareSpec
from repro.core.gemm_spec import EpilogueSpec
from repro.core.policy import POLICIES, get_policy
from repro.tuning.microbench import tune_gemm, tune_grouped_gemm
from repro.tuning.plan_cache import PlanCache, make_key

LAYOUTS = ("dense", "packed", "packed_int4", "packed_fp8")

# Packed-layout payload codec overrides (the precision ladder): plain
# "packed" keeps the policy-derived payload dtype; the codec variants pin
# it to a core.codecs format (launch/serve.py --pack --pack-format).
PACKED_CODECS = {"packed": None, "packed_int4": "int4",
                 "packed_fp8": "fp8e4m3"}

# Policies the serving entrypoint ships (launch/serve.py --policy choices).
SERVE_POLICIES = ("bf16", "bf16_serve", "int8")

# pack_params' default planner M hint — the packed layout's (bk, bn) must
# match what load-time packing derives, or the warmed key never hits.
PACK_M_HINT = 256


@dataclasses.dataclass(frozen=True)
class GemmInstance:
    """One logical GEMM the serving stack launches for a config."""

    role: str                       # attn_q / mlp_gate / logits / ...
    m: int
    n: int
    k: int
    g: int = 1
    epilogue_kind: str = "linear"
    activation: Optional[str] = None
    trans_b: bool = False
    # Policy overrides (the MoE router always runs fp32; expert dots keep
    # f32 activations between GEMM and combine).
    force_policy: Optional[str] = None
    force_out_dtype: Optional[str] = None

    def epilogue(self) -> Optional[EpilogueSpec]:
        if self.epilogue_kind == "linear" and self.activation is None:
            return None
        return EpilogueSpec(kind=self.epilogue_kind,
                            activation=self.activation)


@dataclasses.dataclass(frozen=True)
class ShippedCombo:
    """(config × policy × layout × epilogue) — one plan-cache key."""

    arch: str
    policy: str
    layout: str                     # dense | packed
    instance: GemmInstance
    key: str                        # the cache key serving will look up


@dataclasses.dataclass
class SweepResult:
    combos: List[ShippedCombo]
    warmed: int
    skipped: int                    # deduplicated keys
    elapsed_s: float

    def keys(self) -> List[str]:
        return [c.key for c in self.combos]


def enumerate_gemm_instances(cfg, *, m_tokens: int = 32) -> List[GemmInstance]:
    """The distinct GEMMs one forward pass of ``cfg`` launches for a batch
    of ``m_tokens`` tokens, with the fused epilogues serving ships.

    Mirrors ``models/blocks.py``: per-head attention projections, the
    fused SwiGLU/GeGLU MLP (gate GEMM carries the gated epilogue, the
    down projection the residual fusion), MoE router (fp32) + grouped
    expert GEMMs at capacity token counts, recurrent mixing mats, and the
    logits head (transposed when embeddings are tied).
    """
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    pattern = cfg.pattern
    kinds = set(pattern)
    out: List[GemmInstance] = []

    if kinds & {"dense", "cross", "attn_local", "moe"}:
        out += [
            GemmInstance("attn_q", m_tokens, cfg.n_heads * hd, d),
            GemmInstance("attn_kv", m_tokens, cfg.n_kv_heads * hd, d),
            GemmInstance("attn_out", m_tokens, d, cfg.n_heads * hd),
        ]
    if kinds & {"dense", "cross", "attn_local"}:
        if cfg.mlp == "swiglu":
            out += [
                GemmInstance("mlp_up", m_tokens, f, d),
                GemmInstance("mlp_gate", m_tokens, f, d,
                             epilogue_kind="gated", activation="silu"),
                GemmInstance("mlp_down", m_tokens, d, f,
                             epilogue_kind="residual"),
            ]
        else:
            out += [
                GemmInstance("mlp_up", m_tokens, f, d, activation="gelu"),
                GemmInstance("mlp_down", m_tokens, d, f,
                             epilogue_kind="residual"),
            ]
    if "moe" in kinds and cfg.n_experts:
        e, topk = cfg.n_experts, max(1, cfg.experts_per_token)
        # moe_mlp's capacity rule (capacity_factor=1.25) — the grouped
        # GEMM's m is the per-expert buffer extent, not the token count.
        cap = max(1, int(round(1.25 * topk * m_tokens / e)))
        out.append(GemmInstance("moe_router", m_tokens, e, d,
                                force_policy="fp32"))
        # _expert_dot: f32 outputs between the expert GEMMs and combine;
        # the SwiGLU gating rides the gate GEMM, up/down stay linear.
        out += [
            GemmInstance("moe_up", cap, f, d, g=e,
                         force_out_dtype="float32"),
            GemmInstance("moe_gate", cap, f, d, g=e,
                         epilogue_kind="gated", activation="silu",
                         force_out_dtype="float32"),
            GemmInstance("moe_down", cap, d, f, g=e,
                         force_out_dtype="float32"),
        ]
    if kinds & {"rwkv", "rglru"}:
        out += [
            GemmInstance("rec_mix", m_tokens, d, d),
            GemmInstance("rec_ffn_up", m_tokens, f, d),
            GemmInstance("rec_ffn_down", m_tokens, d, f),
        ]
    out.append(GemmInstance("logits", m_tokens, cfg.vocab, d,
                            trans_b=cfg.tie_embeddings))
    return out


def _instance_dtypes(inst: GemmInstance, policy) -> Tuple[str, str, str]:
    """(a, b, out) dtype strings at kernel-launch time (core/gemm.py:
    quantized policies launch int8 operands; out defaults to
    ``policy.out_dtype`` unless the call site overrides it)."""
    policy = get_policy(inst.force_policy or policy)
    cd = "int8" if policy.quantized else policy.compute_dtype
    out = inst.force_out_dtype or policy.out_dtype
    return cd, cd, out


def _packed_layout_tag(inst: GemmInstance, a_dtype: str, b_dtype: str,
                       hw: HardwareSpec) -> Tuple[str, Tuple[int, int]]:
    """(make_key layout tag, pinned (bk, bn)) of the packed payload
    load-time packing would build — pack_params derives blocks from
    ``plan_gemm(m_hint, n, k, a_dtype, payload_dtype)``."""
    plan = plan_gemm(PACK_M_HINT, inst.n, inst.k, a_dtype, b_dtype, hw=hw)
    return f"packB{plan.bk}x{plan.bn}{b_dtype}", (plan.bk, plan.bn)


def _layout_dtypes(inst: GemmInstance, policy: str,
                   layout: str) -> Tuple[str, str, str]:
    """(a, b, out) dtypes at launch time for a layout variant.  Codec
    layouts pin the payload dtype; the fp8 payload under the int8 policy
    streams bf16 activations (core/gemm.py: no int8 x fp8 dot exists)."""
    a_dtype, b_dtype, out_dtype = _instance_dtypes(inst, policy)
    codec = PACKED_CODECS.get(layout)
    if codec is not None:
        b_dtype = codec
        if a_dtype == "int8" and codec == "fp8e4m3":
            a_dtype = "bfloat16"
    return a_dtype, b_dtype, out_dtype


def _combo_key(inst: GemmInstance, policy: str, layout: str,
               hw: HardwareSpec) -> str:
    a_dtype, b_dtype, out_dtype = _layout_dtypes(inst, policy, layout)
    ep = inst.epilogue()
    layout_tag = ""
    trans_b = inst.trans_b
    if layout.startswith("packed"):
        # The payload tiling is derived at PACK time from the policy's
        # operand dtypes (pack_params._blocks), even when the launch-time
        # a dtype differs (fp8 payload under int8 policy -> bf16 X).
        a_pack, _, _ = _instance_dtypes(inst, policy)
        layout_tag, _ = _packed_layout_tag(inst, a_pack, b_dtype, hw)
        trans_b = False     # transposition is resolved at pack time
    return make_key(
        inst.m, inst.n, inst.k, a_dtype, b_dtype, out_dtype,
        trans_a=False, trans_b=trans_b, beta=0.0, hw=hw, g=inst.g,
        layout=layout_tag, epilogue=ep.tag if ep is not None else "",
    )


def enumerate_shipped_combos(
    archs: Optional[Sequence[str]] = None,
    *,
    policies: Sequence[str] = SERVE_POLICIES,
    layouts: Sequence[str] = LAYOUTS,
    m_tokens: Sequence[int] = (32,),
    smoke: bool = False,
    hw: HardwareSpec = DEFAULT_HW,
) -> List[ShippedCombo]:
    """Every (config × policy × layout × epilogue) combination shipped,
    deduplicated by cache key (two archs sharing a GEMM shape warm it
    once)."""
    for p in policies:
        if p not in POLICIES:
            raise ValueError(f"unknown policy {p!r}; valid: "
                             f"{sorted(POLICIES)}")
    for lay in layouts:
        if lay not in LAYOUTS:
            raise ValueError(f"unknown layout {lay!r}; valid: {LAYOUTS}")
    combos: List[ShippedCombo] = []
    seen: set = set()
    for arch in (archs or cb.ARCH_IDS):
        cfg = cb.get(arch, smoke=smoke)
        for m in m_tokens:
            for inst in enumerate_gemm_instances(cfg, m_tokens=m):
                for policy in policies:
                    for layout in layouts:
                        if layout.startswith("packed") and (
                                inst.force_policy == "fp32"):
                            continue  # the fp32 router is never packed
                        key = _combo_key(inst, policy, layout, hw)
                        if key in seen:
                            continue
                        seen.add(key)
                        combos.append(ShippedCombo(
                            arch=arch, policy=policy, layout=layout,
                            instance=inst, key=key))
    return combos


def _warm_packed(combo: ShippedCombo, cache: PlanCache,
                 hw: HardwareSpec) -> None:
    """Modeled bm-ladder sweep with (bk, bn) pinned to the packed payload
    layout — the same resolution ``kernels/mpgemm.py::_layout_plan`` falls
    back to, persisted so the fallback never runs.  The stored plan's
    (bn, bk) MUST equal the layout's or the read side discards it."""
    inst = combo.instance
    a_dtype, b_dtype, out_dtype = _layout_dtypes(inst, combo.policy,
                                                 combo.layout)
    ep = inst.epilogue()
    n_extra = len(ep.extra_operands) if ep is not None else 0
    # Every quantized payload codec carries per-tile scales -> f32 acc.
    acc = "float32" if get_codec(b_dtype) is not None else None
    a_pack, _, _ = _instance_dtypes(inst, combo.policy)
    _, (bk, bn) = _packed_layout_tag(inst, a_pack, b_dtype, hw)
    base = plan_gemm(inst.m, inst.n, inst.k, a_dtype, b_dtype, out_dtype,
                     acc, extra_mn_inputs=n_extra, hw=hw)
    bm_axis, _, _ = enumerate_block_lattice(inst.m, inst.n, inst.k,
                                            a_dtype, b_dtype, hw=hw)
    budget = int(hw.vmem_bytes * 0.75)
    cands = []
    for bm in dict.fromkeys([base.bm, *bm_axis]):
        cands.append(plan_with_blocks(
            inst.m, inst.n, inst.k, bm, bn, bk, a_dtype, b_dtype,
            out_dtype, acc, extra_mn_inputs=n_extra, hw=hw,
            notes="packed-b swept"))
    plans = [p for p in cands if p.vmem_bytes <= budget] \
        or [min(cands, key=lambda p: p.vmem_bytes)]
    best = min(plans, key=lambda p: max(
        p.flops / hw.peak_flops_bf16, p.hbm_bytes / hw.hbm_bw))
    if inst.g != 1:
        best = grouped_plan_from_2d(best, inst.g)
    cache.put(combo.key, best, meta={
        "mode": "modeled", "source": "perf.sweep", "layout": combo.layout,
        "candidates": len(plans),
    })


def warm_plan_cache(
    combos: Iterable[ShippedCombo],
    cache: PlanCache,
    *,
    mode: str = "modeled",
    hw: HardwareSpec = DEFAULT_HW,
    max_candidates: int = 16,
) -> SweepResult:
    """Tune every combo into ``cache``; the dense path reuses
    ``tune_gemm``/``tune_grouped_gemm`` (so compiled mode works on TPU
    unchanged), the packed path the pinned-(bk, bn) bm ladder."""
    t0 = time.perf_counter()
    combos = list(combos)
    warmed = skipped = 0
    for combo in combos:
        if combo.key in cache:
            skipped += 1
            continue
        inst = combo.instance
        if combo.layout.startswith("packed"):
            _warm_packed(combo, cache, hw)
            warmed += 1
            continue
        a_dtype, b_dtype, out_dtype = _instance_dtypes(inst, combo.policy)
        ep = inst.epilogue()
        kw = dict(mode=mode, cache=cache, save=False, hw=hw,
                  max_candidates=max_candidates, epilogue=ep)
        if inst.g == 1:
            result = tune_gemm(inst.m, inst.n, inst.k, a_dtype, b_dtype,
                               out_dtype, trans_b=inst.trans_b, **kw)
        else:
            result = tune_grouped_gemm(inst.g, inst.m, inst.n, inst.k,
                                       a_dtype, b_dtype, out_dtype, **kw)
        if result.key != combo.key:
            raise AssertionError(
                f"sweep/tuner key drift for {inst.role}: enumerated "
                f"{combo.key!r} but tuner persisted {result.key!r}")
        warmed += 1
    cache.save()
    return SweepResult(combos=combos, warmed=warmed, skipped=skipped,
                       elapsed_s=time.perf_counter() - t0)


def verify_warm(combos: Iterable[ShippedCombo],
                cache: PlanCache) -> List[ShippedCombo]:
    """Combos whose key does NOT hit ``cache`` ([] == fully warm — the
    acceptance gate)."""
    return [c for c in combos if cache.get(c.key) is None]


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Offline plan-cache sweep over every shipped "
                    "(config × policy × layout × epilogue) GEMM")
    ap.add_argument("--out", default="sweep_plans.json",
                    help="PlanCache JSON path to create/extend")
    ap.add_argument("--archs", nargs="*", default=None,
                    choices=cb.ARCH_IDS, help="default: all shipped archs")
    ap.add_argument("--policies", nargs="*", default=list(SERVE_POLICIES),
                    choices=sorted(POLICIES))
    ap.add_argument("--layouts", nargs="*", default=list(LAYOUTS),
                    choices=LAYOUTS)
    ap.add_argument("--m-tokens", nargs="*", type=int, default=[32, 4096],
                    help="token-batch sizes to warm (decode + prefill)")
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "compiled", "interpret", "modeled"))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced SMOKE configs")
    args = ap.parse_args(argv)

    combos = enumerate_shipped_combos(
        args.archs, policies=args.policies, layouts=args.layouts,
        m_tokens=tuple(args.m_tokens), smoke=args.smoke)
    cache = PlanCache(args.out)
    result = warm_plan_cache(combos, cache, mode=args.mode)
    misses = verify_warm(combos, cache)
    print(f"[sweep] {len(combos)} shipped combos "
          f"({result.warmed} tuned, {result.skipped} already cached) "
          f"in {result.elapsed_s:.1f}s -> {args.out} "
          f"({len(cache)} entries)")
    if misses:
        print(f"[sweep] ERROR: {len(misses)} combos NOT warm after the "
              f"sweep:")
        for c in misses[:10]:
            print(f"  {c.arch} {c.policy} {c.layout} "
                  f"{c.instance.role}: {c.key}")
        return 1
    print("[sweep] every enumerated combo has a PlanCache hit — "
          "first-call serving never plans cold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
