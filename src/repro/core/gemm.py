"""``mp_dot`` / ``mp_dot_grouped`` — the paper's technique as first-class,
differentiable ops over ONE spec-driven core.

Every matmul in every model in this framework flows through here — 2-D
projections through :func:`mp_dot`, grouped/batched contractions (MoE expert
GEMMs, per-stream LoRA blocks, generic batched matmuls) through
:func:`mp_dot_grouped`.  Both are thin adapters over a single
``jax.custom_vjp`` core that dispatches on a static
:class:`~repro.core.gemm_spec.GemmSpec` (2-D vs grouped, dense vs packed B,
transposition) plus an :class:`~repro.core.gemm_spec.EpilogueSpec`
(activation, gated-activation and residual-add fusions from the epilogue
registry).  The core:

* applies a :class:`PrecisionPolicy` (fp32 / bf16->f32 / dynamic int8->i32 —
  the paper's Section V multi-precision surface),
* dispatches to the spec-driven Pallas MPGEMM launch (TPU / interpret) —
  which consults the tuned-plan cache, keyed with the epilogue tag — or to
  an XLA ``dot_general`` with identical precision AND epilogue semantics
  (CPU dry-run; XLA picks its own tiling, so plans only affect the kernel
  backends),
* implements ONE VJP whose backward GEMMs use the **fused-transpose**
  kernel variants (dx = dz · Wᵀ, dW = Xᵀ · dz) — the training-time payoff of
  the paper's on-the-fly transposition: no transposed weight copies are ever
  materialized.  Epilogue fusions differentiate through the registry's
  backward rules (packed-int8 weights stay frozen via float0 cotangents;
  float payloads repack their dense cotangent; grouped backward keeps the
  fused-transpose grouped contractions).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import config as cfg
from repro.core.gemm_spec import (
    EpilogueSpec, GemmSpec, apply_epilogue, epilogue_bwd, epilogue_needs_pre,
    get_epilogue, resolve_epilogue,
)
from repro.core.policy import PrecisionPolicy, get_policy, quantize_per_tensor
from repro.kernels.mpgemm import mpgemm_pallas_spec
from repro.packing.layout import PackedOperand, is_packed
from repro.sparse.layout import TileSparseOperand, is_sparse

_LINEAR = EpilogueSpec()


def _dims(spec: GemmSpec):
    """dot_general dims for the XLA backend (grouped: group = batch axis)."""
    if spec.grouped:
        ca = 1 if spec.trans_a else 2
        cb = 2 if spec.trans_b else 1
        return (((ca,), (cb,)), ((0,), (0,)))
    ca = 0 if spec.trans_a else 1
    cb = 1 if spec.trans_b else 0
    return (((ca,), (cb,)), ((), ()))


def _xla_epilogue(epilogue, acc, bias, scale, extras, grouped):
    """The kernel's fused epilogue, re-played on a full XLA accumulator —
    same ``apply_epilogue`` implementation, so backends cannot drift."""
    if bias is not None:
        bias = (bias.reshape(bias.shape[0], 1, -1) if grouped
                else bias.reshape(1, -1))
    return apply_epilogue(epilogue, acc, bias=bias, scale=scale,
                          extras=extras)


def _note_xla_dispatch(x, w, spec, epilogue, ep_def, out_dtype):
    """Launch census + modeled-plan telemetry for GEMMs dispatched to XLA.

    Mirrors the kernel path's accounting in ``kernels/mpgemm.py`` so the
    per-spec launch counters and the plan-cache hit/miss series do not go
    dark on non-kernel backends (CPU serving, the explicit ``backend="xla"``
    A/B baseline).  XLA picks its own tiling, so the resolved plan is used
    for MODELING only (span bytes/FLOPs) and never steers the dispatch.
    Trace-time host code — a cached jit executable never re-enters it.
    """
    if not (obs.metrics_enabled() or obs.tracing_enabled()):
        return
    from repro.core.blocking import grouped_plan_from_2d, plan_gemm
    from repro.tuning.plan_cache import (
        lookup_plan, make_key, note_analytic_fallback,
    )
    g = x.shape[0] if spec.grouped else 1
    m = x.shape[-1] if spec.trans_a else x.shape[-2]
    k = x.shape[-2] if spec.trans_a else x.shape[-1]
    n = w.shape[-2] if spec.trans_b else w.shape[-1]
    n_extra_mn = sum(1 for nm in ep_def.extra_operands
                     if nm not in ep_def.row_operands)
    with obs.span("gemm.plan", m=m, n=n, k=k, g=g):
        plan = lookup_plan(
            m, n, k, x.dtype, w.dtype, out_dtype,
            trans_a=spec.trans_a, trans_b=spec.trans_b,
            beta=epilogue.beta, g=g, epilogue=epilogue.tag,
            analytic_memo=True)
        if plan is None:
            plan = plan_gemm(m, n, k, x.dtype, w.dtype, out_dtype=out_dtype,
                             beta=epilogue.beta, extra_mn_inputs=n_extra_mn)
            if spec.grouped:
                plan = grouped_plan_from_2d(plan, g)
            note_analytic_fallback(make_key(
                m, n, k, x.dtype, w.dtype, out_dtype,
                trans_a=spec.trans_a, trans_b=spec.trans_b,
                beta=epilogue.beta, g=g, epilogue=epilogue.tag), plan)
        obs.annotate(bytes=plan.hbm_bytes, flops=plan.flops, cmr=plan.cmr)
    obs.counter_inc("gemm_launches_total",
                    help="GEMM launches by spec combination",
                    layout="dense", codec="none", epilogue=epilogue.kind,
                    sparse="false", grouped=str(spec.grouped).lower())


def _apply_gemm(x, w, bias, extras, spec: GemmSpec, epilogue: EpilogueSpec,
                policy: PrecisionPolicy, backend: str, acc_dtype=None):
    """One GEMM under a policy on the selected backend — THE dispatch point.

    The single home of the policy logic for every spec combination
    (2-D/grouped × dense/packed × every registered epilogue):

    * Packed ``w`` (:class:`PackedOperand`): kernel backends read the
      payload directly — identity tile index maps, transpose resolved at
      pack time, per-tile int8 dequant riding the accumulation — so NO
      per-call operand prep (cast / dequant / strided re-layout) is
      materialized.  The XLA backend, which picks its own tiling, unpacks
      once and reuses the dense-path policy logic below.
    * ``w`` is a dense array or a :class:`PackedOperand` — NEVER a
      static-int8 {"q","scale"} dict: the differentiable wrappers
      dequantize dicts BEFORE the custom-VJP core (:func:`_dequant_static`)
      so dict primals never need dict cotangents, and XLA still fuses that
      dequant into the consuming GEMM read.
    * The compute-dtype down-cast is pinned shard-local BEFORE any
      FSDP/EP all-gather: without the barrier GSPMD gathers the f32 master
      weights and converts after, doubling gather wire bytes (measured on
      mixtral train_4k — EXPERIMENTS.md §Perf).  Safe under
      differentiation: it only ever runs inside the custom-VJP core, where
      JAX never needs a JVP rule for the barrier.
    * ``acc_dtype`` overrides the accumulator/partial-sum dtype on the XLA
      backend: backward GEMMs pass bf16 so that TP/EP partial-sum
      all-reduces move bf16 instead of f32 (halves gradient wire bytes).
      Kernel backends accumulate per the plan's acc dtype instead (plans
      own kernel numerics; f32/i32 VMEM scratch).
    """
    grouped = spec.grouped
    out_dtype = spec.out_dtype or policy.out_dtype
    kernel_backend = backend in ("pallas", "interpret")
    interp = backend == "interpret"

    # Registry pre-stage (quant_in): per-token activation quantization of X
    # BEFORE the launch — plain jnp ops, so quantize -> GEMM -> dequant is
    # still ONE kernel launch; the produced row scales ride the extras
    # stream into the fused dequant tail.
    ep_def = get_epilogue(epilogue.kind)
    pre_quant = ep_def.pre is not None
    if pre_quant:
        if bias is not None:
            raise ValueError(
                f"epilogue {epilogue.kind!r} does not take a bias (the "
                "fused per-row dequant would rescale it)")
        x, pre_extras = ep_def.pre(epilogue, x)
        extras = tuple(pre_extras) + tuple(extras)

    def _kernel(a, b, wp, scale, ws=None):
        op = b if b is not None else wp if wp is not None else ws
        return mpgemm_pallas_spec(
            a, op, bias=bias, scale=scale,
            extras=extras, spec=spec, epilogue=epilogue,
            out_dtype=out_dtype, interpret=interp)

    if is_sparse(w):
        # Tile-sparse B: kernel backends walk only the stored tiles (the
        # sparse launch path); the policy logic mirrors the packed branch
        # — the payload IS the weight-side storage, so only the x side
        # ever needs a per-call cast/quantize.
        layout = w.layout
        if kernel_backend and (pre_quant or not (policy.quantized
                                                 and layout.dtype != "int8")):
            if pre_quant:
                # X is already row-quantized int8; an int8 payload dots in
                # int32 against it, a float payload upcasts in-kernel.
                if layout.dtype == "int8":
                    return _kernel(x, None, None, None, w)
                w = w.astype(policy.compute_dtype)
                return _kernel(x.astype(jnp.dtype(policy.compute_dtype)),
                               None, None, None, w)
            if policy.quantized:
                xq, sx = quantize_per_tensor(x)
                return _kernel(xq, None, None, sx, w)
            xc = x.astype(jnp.dtype(policy.compute_dtype))
            if layout.dtype != "int8":
                w = w.astype(policy.compute_dtype)
            return _kernel(xc, None, None, None, w)
        # XLA fallback — or a float payload under the dynamic-int8 policy:
        # densify (zeros at pruned tiles) and reuse the dense-path logic.
        from repro.sparse.sparsify import densify_operand
        w = densify_operand(w)
        spec = dataclasses.replace(spec, sparse=False, tile_scaled=False,
                                   trans_b=False)

    if is_packed(w):
        layout = w.layout
        native = kernel_backend and layout.kernel_native
        if native and (pre_quant or layout.per_tile_scales
                       or not policy.quantized):
            if pre_quant:
                # X is already row-quantized int8.  Quantized payloads
                # (int8/int4/fp8) dequant via their per-tile scales riding
                # the accumulation; float payloads upcast the int X values
                # in-kernel (the row scale still dequantizes in the tail).
                if layout.per_tile_scales:
                    return _kernel(x, None, w, None)
                w = w.astype(policy.compute_dtype)
                return _kernel(x.astype(jnp.dtype(policy.compute_dtype)),
                               None, w, None)
            if policy.quantized:
                if layout.codec is not None and not layout.codec.integer:
                    # fp8 payload under the dynamic-int8 policy: there is
                    # no int8 x fp8 dot — stream bf16 activations against
                    # the fp8 tiles (per-tile scales still dequant).
                    return _kernel(x.astype(jnp.bfloat16), None, w, None)
                # Dynamic x-side quantization only: the weight side is
                # already int-valued with per-tile scales in the payload.
                xq, sx = quantize_per_tensor(x)
                return _kernel(xq, None, w, sx)
            xc = x.astype(jnp.dtype(policy.compute_dtype))
            if not layout.per_tile_scales:
                w = w.astype(policy.compute_dtype)  # no-op when packed right
            return _kernel(xc, None, w, None)
        # XLA fallback — a float payload under the dynamic-int8 policy
        # (whose per-tensor weight quantization needs a dense array), a
        # bit-emulated codec the kernel can't decode, or a non-kernel
        # backend: unpack once and reuse the dense-path logic.
        from repro.packing.pack import unpack_operand
        w = unpack_operand(w, backend=backend if native else None)
        spec = dataclasses.replace(spec, packed=False, tile_scaled=False,
                                   trans_b=False)

    if not kernel_backend:
        _note_xla_dispatch(x, w, spec, epilogue, ep_def, out_dtype)

    if pre_quant:
        # Dense weights under activation quantization: per-tensor quantize
        # the weight side so the dot runs int8 x int8 -> int32; the weight
        # scale rides the scalar dequant slot, the row scales the tail.
        wq, sw = quantize_per_tensor(w)
        if kernel_backend:
            return _kernel(x, wq, None, sw)
        acc = jax.lax.dot_general(x, wq, _dims(spec),
                                  preferred_element_type=jnp.int32)
        return _xla_epilogue(epilogue, acc, bias, sw, extras,
                             grouped).astype(out_dtype)

    if policy.quantized:
        xq, sx = quantize_per_tensor(x)
        wq, sw = quantize_per_tensor(w)
        scale = sx * sw
        if kernel_backend:
            return _kernel(xq, wq, None, scale)
        acc = jax.lax.dot_general(xq, wq, _dims(spec),
                                  preferred_element_type=jnp.int32)
        return _xla_epilogue(epilogue, acc, bias, scale, extras,
                             grouped).astype(out_dtype)

    cd = jnp.dtype(policy.compute_dtype)
    xc = x.astype(cd)
    wc = w.astype(cd)
    if wc.dtype != w.dtype:
        wc = jax.lax.optimization_barrier(wc)  # see docstring
    if kernel_backend:
        return _kernel(xc, wc, None, None)
    acc = jax.lax.dot_general(
        xc, wc, _dims(spec),
        preferred_element_type=jnp.dtype(acc_dtype or policy.acc_dtype),
    )
    return _xla_epilogue(epilogue, acc, bias, None, extras,
                         grouped).astype(out_dtype)


def _bwd_flavor(policy: PrecisionPolicy):
    """(backward policy, backward partial-sum dtype) — see :func:`_gemm_bwd`."""
    bwd_policy = get_policy("fp32" if policy.name == "fp32" else "bf16")
    bwd_acc = "float32" if policy.name == "fp32" else "bfloat16"
    return bwd_policy, bwd_acc


def _packed_weight_cotangent(wp: PackedOperand, dw_dense) -> PackedOperand:
    """Cotangent pytree for a packed-weight primal.

    Float payloads: pack/unpack is a LINEAR bijection onto the tile grid
    (zero pads aside), so the payload cotangent is simply the packed dense
    gradient — packed weights stay trainable.  int8 payloads (per-tile
    quantized) have no usable tangent space: integer leaves get float0
    zeros (JAX's unit cotangent for int primals), scales zeros — the
    weight is frozen, the standard serving configuration.
    """
    from repro.packing.pack import pack_reference
    layout = wp.layout
    if layout.per_tile_scales:
        return PackedOperand(
            np.zeros(wp.payload.shape, jax.dtypes.float0),
            jnp.zeros_like(wp.scales), layout)
    # dw_dense is in the LOGICAL (k, n) orientation (the bwd GEMMs resolve
    # the transpose), so the cotangent pack must not re-apply the layout's
    # recorded source transpose.
    payload_ct, _ = pack_reference(
        dw_dense, dataclasses.replace(layout, trans_w=False))
    return PackedOperand(payload_ct, None, layout)


def _sparse_weight_cotangent(ws: TileSparseOperand,
                             dw_dense) -> TileSparseOperand:
    """Cotangent pytree for a tile-sparse weight primal.

    The defining property of the sparse op's VJP: the dense gradient is
    MASKED to the stored tiles — pruned tiles are structural zeros with no
    tangent space, so training under a fixed pattern can never resurrect
    them (and the trailing anchor zero tile stays a constant: zero
    cotangent).  int8 payloads are frozen via float0, exactly as packed.
    """
    from repro.sparse.sparsify import payload_cotangent
    layout = ws.layout
    if layout.per_tile_scales:
        return TileSparseOperand(
            np.zeros(ws.payload.shape, jax.dtypes.float0),
            jnp.zeros_like(ws.scales), layout)
    return TileSparseOperand(payload_cotangent(dw_dense, layout), None,
                             layout)


# --- the one differentiable core ---------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _gemm_core(x, w, bias, extras, spec: GemmSpec, epilogue: EpilogueSpec,
               policy_name: str, backend: str):
    """THE custom-VJP core: every mp_dot / mp_dot_grouped call lands here.

    ``spec``/``epilogue`` are static (hashable) and carry the full dispatch
    decision; ``w`` is a dense array or a :class:`PackedOperand` pytree
    (never a {"q","scale"} dict — the wrappers dequantize those first so
    dict primals never need dict cotangents); ``extras`` is the tuple of
    epilogue fusion operands in registry order.
    """
    return _apply_gemm(x, w, bias, extras, spec, epilogue,
                       get_policy(policy_name), backend)


def _gemm_fwd(x, w, bias, extras, spec, epilogue, policy_name, backend):
    y = _gemm_core(x, w, bias, extras, spec, epilogue, policy_name, backend)
    return y, (x, w, bias, extras)


def _gemm_bwd(spec: GemmSpec, epilogue: EpilogueSpec, policy_name, backend,
              res, dy):
    """One backward rule for every spec: fused-transpose GEMMs + registry
    epilogue backward.

    Non-quantized sibling precision (STE for int8), bf16 partial sums so
    TP/FSDP/EP gradient reductions move bf16 on the wire (see
    :func:`_bwd_flavor`).  Packed weights: the payload's layout serves the
    FORWARD read pattern; backward contracts over N, for which the dense
    on-the-fly-transpose kernel path already exists — so the weight is
    unpacked once and the gradient re-packed (int8 payloads stay frozen via
    float0).  Fused epilogues recompute the pre-tail value z only when the
    registry entry's backward needs it (one extra GEMM — standard
    rematerialization; the fused forward never materializes z).
    """
    x, w, bias, extras = res
    policy = get_policy(policy_name)
    bwd_policy, bwd_acc = _bwd_flavor(policy)
    grouped = spec.grouped

    packed = is_packed(w)
    sparse = is_sparse(w)
    if packed:
        from repro.packing.pack import unpack_operand
        kb = backend if backend in ("pallas", "interpret") else None
        w_dense = unpack_operand(w, backend=kb)  # (k,n)/(g,k,n), trans resolved
        w_trans = False
    elif sparse:
        # Densify once (zeros at pruned tiles): backward contracts over N,
        # for which the dense on-the-fly-transpose path exists; the weight
        # cotangent is then masked back to the stored tiles.
        from repro.sparse.sparsify import densify_operand
        w_dense = densify_operand(w)
        w_trans = False
    else:
        w_dense = w
        w_trans = spec.trans_b

    z = None
    if epilogue_needs_pre(epilogue):
        zspec = dataclasses.replace(
            spec, packed=False, sparse=False, tile_scaled=False,
            trans_b=w_trans, ragged=False, out_dtype="float32")
        z = _apply_gemm(x, w_dense, bias, (), zspec,
                        EpilogueSpec(alpha=epilogue.alpha), bwd_policy,
                        backend)
    dz, dextras = epilogue_bwd(epilogue, z, extras, dy.astype(jnp.float32))

    # Chain through the epilogue's alpha pre-scale (z = alpha·acc + bias, so
    # dacc = alpha·dz); bias adds AFTER alpha, so dbias below stays unscaled.
    # The dynamic-int8 dequant scale is deliberately NOT chained (STE: the
    # backward runs in the non-quantized sibling policy).
    dzg = dz * jnp.asarray(epilogue.alpha, dz.dtype) \
        if epilogue.alpha != 1.0 else dz

    # dx = dzg @ op(w)^T : if w stored (k,n) -> dzg(m,n) x w(k,n)^T == trans_b=True
    #                      if w stored (n,k) (trans_w) -> plain dzg @ w.
    dx_spec = dataclasses.replace(
        spec, packed=False, sparse=False, tile_scaled=False, trans_a=False,
        trans_b=not w_trans, ragged=False, out_dtype=str(x.dtype))
    dx = _apply_gemm(dzg, w_dense, None, (), dx_spec, _LINEAR, bwd_policy,
                     backend, acc_dtype=bwd_acc)

    # dw: (k,n) = x^T @ dzg ; transposed storage: (n,k) = dzg^T @ x.
    if (packed or sparse) and w.layout.per_tile_scales:
        dw_dense = None  # int8 payload: no tangent space, frozen weight
    else:
        dw_spec = dataclasses.replace(
            spec, packed=False, sparse=False, tile_scaled=False,
            trans_a=True, trans_b=False, ragged=False,
            out_dtype=str(w_dense.dtype))
        dw_dense = (_apply_gemm(dzg, x, None, (), dw_spec, _LINEAR,
                                bwd_policy, backend, acc_dtype=bwd_acc)
                    if w_trans else
                    _apply_gemm(x, dzg, None, (), dw_spec, _LINEAR,
                                bwd_policy, backend, acc_dtype=bwd_acc))
    dw = (_packed_weight_cotangent(w, dw_dense) if packed
          else _sparse_weight_cotangent(w, dw_dense) if sparse
          else dw_dense)

    # f32 accumulation for the reduction, cast back to the primal's dtype
    # (custom-VJP cotangents must match primal dtypes).
    dbias = None
    if bias is not None:
        dbias = jnp.sum(dz, axis=1 if grouped else 0,
                        dtype=jnp.float32).astype(bias.dtype)
    return dx, dw, dbias, dextras


_gemm_core.defvjp(_gemm_fwd, _gemm_bwd)


# --- op-level spec assembly ---------------------------------------------------

def _build_epilogue(epilogue, activation, gate, residual, epilogue_operands,
                    quant_in=False):
    """Resolve the op-level EpilogueSpec + ordered extras tuple.

    Convenience kwargs (``activation``/``gate``/``residual``) infer the
    registry kind; an explicit ``epilogue`` spec wins, with
    ``epilogue_operands`` naming any custom entry's streamed operands.
    ``quant_in=True`` selects the activation-quantization family (explicit
    opt-in — pre-stage kinds are never inferred from operands).  The
    shared registry-driven resolution lives in core/gemm_spec.py.
    """
    named = {"gate": gate, "residual": residual}
    if epilogue_operands:
        named.update(epilogue_operands)
    if quant_in:
        if epilogue is not None:
            raise ValueError(
                "pass quant_in=True OR an explicit epilogue spec, not both")
        if gate is not None:
            raise ValueError(
                "quant_in does not compose with the gated epilogue")
        kind = "quant_in_residual" if residual is not None else "quant_in"
        epilogue = EpilogueSpec(kind=kind, activation=activation)
        activation = None
    epilogue, extras = resolve_epilogue(named, epilogue=epilogue,
                                        activation=activation)
    if epilogue.beta != 0.0:
        raise ValueError(
            "beta·C accumulation is a kernel-level epilogue "
            "(mpgemm_pallas); mp_dot has no C operand")
    return epilogue, extras


def _dequant_static(w, policy):
    """Dequantize a static-int8 {"q","scale"} dict BEFORE the custom-VJP
    core: the bwd rule contracts against w and must see an array primal (a
    dict residual has no dtype and no array cotangent).  XLA still fuses
    the dequant into the GEMM read; differentiation flows through the
    dequant natively."""
    from repro.core.quantization import dequantize_tensor, is_quantized
    if not is_quantized(w):
        return w
    return dequantize_tensor(
        w, jnp.float32 if policy.quantized
        else jnp.dtype(policy.compute_dtype))


def _resolve_operand(name, b, w, b_sparse):
    """Collapse the legacy ``w=``/``b_sparse=`` keywords into the
    polymorphic ``b`` operand (dense array / PackedOperand /
    TileSparseOperand — dispatch is by type).  The keywords survive only
    as DeprecationWarning shims."""
    if sum(x is not None for x in (b, w, b_sparse)) != 1:
        raise ValueError(f"{name}: exactly one of b / w / b_sparse "
                         "is required")
    if w is not None:
        obs.warn_deprecated(
            f"{name}.w",
            f"{name}(w=...) is deprecated; pass the operand positionally "
            "as `b`", stacklevel=3)
        return w
    if b_sparse is not None:
        obs.warn_deprecated(
            f"{name}.b_sparse",
            f"{name}(b_sparse=...) is deprecated; pass the operand as the "
            "polymorphic `b` argument (dispatch is by operand type)",
            stacklevel=3)
        return b_sparse
    return b


def mp_dot(
    x: jax.Array,
    b: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    *,
    w: Optional[jax.Array] = None,
    b_sparse: Optional[TileSparseOperand] = None,
    policy="bf16",
    trans_w: bool = False,
    backend: Optional[str] = None,
    out_dtype=None,
    activation: Optional[str] = None,
    gate: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    epilogue: Optional[EpilogueSpec] = None,
    epilogue_operands: Optional[dict] = None,
    quant_in: bool = False,
) -> jax.Array:
    """y[..., n] = tail(x[..., k] @ (b[n, k]ᵀ if trans_w else b[k, n]) + bias).

    ``tail`` is the registry epilogue: ``activation`` alone fuses an
    activation into the GEMM's store; ``gate`` fuses ``act(·) · gate`` (the
    SwiGLU/GeGLU gating step — one kernel launch instead of a GEMM plus an
    elementwise pass); ``residual`` fuses ``act(·) + residual``.  Both take
    an operand shaped like the output.  All fusions differentiate through
    the registry's backward rules.

    ``trans_w=True`` is the on-the-fly-transposition path — used e.g. for
    tied-embedding logits (weights stored (vocab, d_model)).

    ``b`` is POLYMORPHIC — dispatch is by operand type, not by keyword:

    * a dense array — the plain mixed-precision GEMM;
    * a :class:`repro.packing.PackedOperand` (pre-packed at parameter-load
      time): the forward then reads the tiled payload directly — no
      per-call cast/dequant/transposition — and ``trans_w`` must match the
      orientation recorded at pack time (the transpose is already resolved
      inside the payload);
    * a :class:`repro.sparse.TileSparseOperand`: the forward then visits
      ONLY the stored tiles (grid = stored-tile schedule, scalar-prefetched
      index maps), the custom VJP masks the weight cotangent to the stored
      tiles (pruned tiles have no tangent space — a fixed pattern can never
      be resurrected by training), and ``dx`` contracts against the
      densified weight.  Composes with every registry epilogue and
      precision policy; int8 payloads are frozen via float0 like packed
      int8.

    ``quant_in=True`` turns on per-token activation quantization: a
    registry pre-stage computes per-row amax scales for ``x``, the GEMM
    runs int8 (against per-tile-quantized packed payloads or a per-tensor-
    quantized dense weight), and the per-row dequant (+activation
    [+residual]) is fused into the epilogue — quantize -> GEMM -> dequant
    in ONE kernel launch.  The backward is straight-through (gradients of
    the float GEMM, ignoring the rounding).  Excludes ``bias``/``gate``.

    ``w=`` and ``b_sparse=`` are deprecated keyword aliases for ``b``.
    """
    w = _resolve_operand("mp_dot", b, w, b_sparse)
    policy = get_policy(policy)
    backend = backend or cfg.get_gemm_backend()
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    if bias is not None:
        bias = bias.reshape(-1)
    epilogue, extras = _build_epilogue(epilogue, activation, gate, residual,
                                       epilogue_operands, quant_in=quant_in)
    extras = tuple(e.reshape(-1, e.shape[-1]) for e in extras)
    out_s = str(jnp.dtype(out_dtype)) if out_dtype is not None else None
    if is_packed(w) or is_sparse(w):
        kind = "PackedOperand" if is_packed(w) else "TileSparseOperand"
        if w.layout.g != 1:
            raise ValueError(f"grouped {kind}: use mp_dot_grouped")
        if trans_w != w.layout.trans_w:
            raise ValueError(
                f"trans_w={trans_w} but the operand was packed with "
                f"trans_w={w.layout.trans_w} (transposition is resolved at "
                f"pack time)")
        n = w.layout.n
        spec = GemmSpec(packed=is_packed(w), sparse=is_sparse(w),
                        tile_scaled=w.layout.per_tile_scales,
                        out_dtype=out_s)
    else:
        w = _dequant_static(w, policy)
        n = w.shape[0] if trans_w else w.shape[-1]
        spec = GemmSpec(trans_b=trans_w, out_dtype=out_s)
    y2d = _gemm_core(x2d, w, bias, extras, spec, epilogue, policy.name,
                     backend)
    return y2d.reshape(*lead, n)


# --- grouped / batched op ----------------------------------------------------

def mp_dot_grouped(
    x: jax.Array,
    b: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    *,
    w: Optional[jax.Array] = None,
    b_sparse: Optional[TileSparseOperand] = None,
    policy="bf16",
    trans_w: bool = False,
    backend: Optional[str] = None,
    group_sizes: Optional[jax.Array] = None,
    out_dtype=None,
    activation: Optional[str] = None,
    gate: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    epilogue: Optional[EpilogueSpec] = None,
    epilogue_operands: Optional[dict] = None,
    quant_in: bool = False,
) -> jax.Array:
    """y[g, m, n] = tail(x[g, m, k] @ (b[g, n, k]ᵀ if trans_w else b[g, k, n]) + bias[g, n]).

    The grouped sibling of :func:`mp_dot`: G independent GEMMs — MoE expert
    blocks, batched projections — in ONE kernel launch with the group as the
    leading grid axis, under the same precision policies, plan cache (keyed
    with the extra ``g`` dimension and the epilogue tag), fused-transpose
    custom VJP, and registry epilogues (``gate``/``residual`` are (G, M, N)
    operands — e.g. the fused MoE SwiGLU gating).

    ``group_sizes`` (shape (G,), int) marks ragged groups: rows ``>=
    group_sizes[g]`` of each output group are forced to zero, so capacity-
    padded expert buffers contribute neither output nor (via the masked
    cotangent) gradient.  The mask sits outside the custom VJP, so autodiff
    handles it natively.

    ``out_dtype`` overrides the policy's output dtype — MoE keeps f32
    activations between the expert GEMMs and the combine, matching the
    accumulator precision.

    ``b`` is polymorphic like :func:`mp_dot`'s: a dense (G, K, N) array, a
    grouped :class:`repro.packing.PackedOperand`, or a grouped
    :class:`repro.sparse.TileSparseOperand` — the sparse form walks only
    the union of every group's stored tiles — per-expert tile pruning
    shrinks the launch grid itself — with the same masked-cotangent VJP as
    :func:`mp_dot`.  ``w=`` and ``b_sparse=`` are deprecated keyword
    aliases for ``b``.
    """
    if x.ndim != 3:
        raise ValueError(f"mp_dot_grouped expects x of rank 3, got {x.shape}")
    w = _resolve_operand("mp_dot_grouped", b, w, b_sparse)
    policy = get_policy(policy)
    backend = backend or cfg.get_gemm_backend()
    epilogue, extras = _build_epilogue(epilogue, activation, gate, residual,
                                       epilogue_operands, quant_in=quant_in)
    out_s = str(jnp.dtype(out_dtype)) if out_dtype is not None else None
    if is_packed(w) or is_sparse(w):
        if w.layout.g != x.shape[0]:
            raise ValueError(
                f"group mismatch: x has {x.shape[0]}, payload {w.layout.g}")
        if trans_w != w.layout.trans_w:
            raise ValueError(
                f"trans_w={trans_w} but the operand was packed with "
                f"trans_w={w.layout.trans_w}")
        spec = GemmSpec(grouped=True, packed=is_packed(w),
                        sparse=is_sparse(w),
                        tile_scaled=w.layout.per_tile_scales,
                        ragged=group_sizes is not None, out_dtype=out_s)
    else:
        w = _dequant_static(w, policy)
        spec = GemmSpec(grouped=True, trans_b=trans_w,
                        ragged=group_sizes is not None, out_dtype=out_s)
    if bias is not None and bias.ndim == 1:
        # Normalize a shared (N,) bias to (G, N) BEFORE the custom-VJP core:
        # outside it autodiff sum-reduces the (G, N) bias cotangent back to
        # (N,); inside, backends would disagree on broadcasting.
        bias = jnp.broadcast_to(bias[None, :], (x.shape[0], bias.shape[0]))
    y = _gemm_core(x, w, bias, extras, spec, epilogue, policy.name, backend)
    if group_sizes is not None:
        sizes = jnp.asarray(group_sizes, jnp.int32).reshape(-1, 1, 1)
        rows = jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
        y = jnp.where(rows < sizes, y, jnp.zeros_like(y))
    return y


def _as_grouped_matmul(spec: str, n_ops: int) -> Optional[bool]:
    """Is ``spec`` a grouped matmul ``Xab,Xbc->Xac`` (any letters)?

    Returns ``trans_w`` (False for ``Xab,Xbc->Xac``, True for
    ``Xab,Xcb->Xac``) or None when the spec is not a grouped matmul.
    """
    if n_ops != 2:
        return None
    try:
        ins, out = spec.replace(" ", "").split("->")
        a, b = ins.split(",")
    except ValueError:
        return None
    if not (len(a) == len(b) == len(out) == 3 and len(set(a)) == 3):
        return None
    if not (a[0] == b[0] == out[0] and out[1] == a[1]):
        return None
    if b[1] == a[2] and out[2] == b[2] and len({a[0], a[1], a[2], b[2]}) == 4:
        return False           # Xab,Xbc->Xac
    if b[2] == a[2] and out[2] == b[1] and len({a[0], a[1], a[2], b[1]}) == 4:
        return True            # Xab,Xcb->Xac (stored-transposed rhs)
    return None


def mp_einsum(spec: str, *operands, policy="bf16") -> jax.Array:
    """Policy-aware einsum for non-2D contractions (attention score/value).

    Grouped-matmul specs (``gmk,gkn->gmn`` and the stored-transposed
    ``gmk,gnk->gmn``, any letters) are routed through :func:`mp_dot_grouped`
    — i.e. through the spec-driven MPGEMM core and plan cache — rather than
    a raw einsum.  Anything else runs on XLA with the policy's
    compute/accumulate dtypes; quantized policies fall back to their bf16
    sibling there (per-slice dynamic quantization needs the grouped path).
    """
    trans_w = _as_grouped_matmul(spec, len(operands))
    if trans_w is not None and all(
        jnp.dtype(o.dtype).kind == "f" for o in operands
    ):
        return mp_dot_grouped(operands[0], operands[1], policy=policy,
                              trans_w=trans_w)
    policy = get_policy(policy)
    if policy.quantized:
        policy = get_policy("bf16")
    cd = jnp.dtype(policy.compute_dtype)
    ops = [o.astype(cd) if jnp.dtype(o.dtype).kind == "f" else o for o in operands]
    out = jnp.einsum(
        spec, *ops, preferred_element_type=jnp.dtype(policy.acc_dtype)
    )
    return out.astype(policy.out_dtype)
