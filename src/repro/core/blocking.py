"""Analytic block-size planner for MPGEMM-TPU.

This is the TPU adaptation of the paper's cache-aware partitioning model
(Section IV-B, equations (1)-(3)):

  paper eq (1): working set of packed blocks < shared-L2 (8 MB)
      -> here: double-buffered A/B input blocks + resident accumulator must
         fit the VMEM budget.

  paper eq (2): TLB-entry bound on kc
      -> no TLB on TPU.  Replaced by a DMA-granularity bound: every block's
         minor (lane) dimension must span >= ``min_dma_row_bytes`` contiguous
         bytes, the analogue of issuing four-Z-register (256 B) grouped loads
         instead of single-Z (64 B) loads.

  paper eq (3): maximize compute-to-memory ratio (CMR)
      -> same objective.  For a K-innermost revisiting grid the total HBM
         traffic is
            bytes = A_bytes * ceil(N/bn) + B_bytes * ceil(M/bm) + C_bytes
         so CMR maximization == traffic minimization.  We solve the
         continuous relaxation (Lagrange: bm == bn at the optimum, bk as
         large as capacity allows) and then refine over the hardware-aligned
         discrete lattice, mirroring the paper's "analytical model + final
         alignment to mr/nr".

The planner emits a :class:`GemmPlan` consumed by ``kernels/mpgemm.py`` (as
BlockSpec shapes) and by benchmarks (as the predicted-traffic model).

The analytic model is deliberately open-loop — it never sees a measurement.
``repro.tuning`` closes the loop: :func:`enumerate_block_lattice` exposes the
exact candidate lattice the planner searches, :func:`plan_with_blocks` prices
an arbitrary lattice point, and :func:`plan_to_dict` / :func:`plan_from_dict`
let tuned plans persist across processes (tuning/plan_cache.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.codecs import dtype_bits, dtype_bytes, get_codec, plan_dtype
from repro.core.constants import DEFAULT_HW, HardwareSpec


def _dtype_bytes(dtype):
    """Bytes per element — fractional for sub-byte payload codecs (int4
    moves half a byte of HBM per weight element; core/codecs.py)."""
    return dtype_bytes(dtype)


def _min_span(dtype, floor_bytes: int, align: int) -> int:
    """Smallest ``align``-multiple element count whose contiguous row
    covers ``floor_bytes`` — computed in BITS so sub-byte codecs get an
    exact integer answer (int4: 512 B -> 1024 elements)."""
    bits = dtype_bits(dtype)
    elems = (floor_bytes * 8 + bits - 1) // bits
    return max(align, _round_up(elems, align))


def _sublane(hw: HardwareSpec, dtype) -> int:
    """Second-minor granularity; sub-byte codecs tile like their storage
    bytes (int4 nibbles live in int8 bytes -> the (32, 128) int8 tile)."""
    return hw.sublane(max(1, dtype_bits(dtype) // 8))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _round_down(x: int, m: int) -> int:
    return max(m, (x // m) * m)


@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """A fully-specified blocking decision for one (possibly grouped) GEMM.

    ``g > 1`` marks a grouped/batched instance: G independent M x N x K
    problems executed by one kernel launch with the group as the leading
    grid axis.  ``grid`` stays the per-group (M/bm, N/bn, K/bk) triple (the
    kernel prepends G); ``flops``/``hbm_bytes`` cover all G groups, so the
    roofline and CMR terms price the whole launch.
    """

    m: int
    n: int
    k: int
    bm: int
    bn: int
    bk: int
    a_dtype: str
    b_dtype: str
    out_dtype: str
    acc_dtype: str
    # Derived.
    grid: Tuple[int, int, int]
    vmem_bytes: int          # modeled VMEM working set
    hbm_bytes: int           # modeled HBM traffic for the whole GEMM
    flops: int               # 2*G*M*N*K
    cmr: float               # flops / hbm_bytes (the paper's eq (3) value)
    k_rem: int               # K % bk (0 -> no K-edge predication needed)
    notes: str = ""
    g: int = 1               # group/batch count (1 == plain 2-D GEMM)

    @property
    def arithmetic_intensity(self) -> float:
        return self.cmr

    def describe(self) -> str:
        shape = f"{self.m}x{self.n}x{self.k}"
        if self.g != 1:
            shape = f"{self.g}x" + shape
        return (
            f"GemmPlan[{shape} {self.a_dtype}->"
            f"{self.out_dtype}] blocks=({self.bm},{self.bn},{self.bk}) "
            f"grid={self.grid} vmem={self.vmem_bytes/2**20:.2f}MiB "
            f"CMR={self.cmr:.1f} {self.notes}"
        )


def _resolve_dtypes(a_dtype, b_dtype=None, out_dtype=None, acc_dtype=None):
    """Canonical (a, b, out, acc) dtype strings under the policy defaults.

    int inputs accumulate in int32 and default to an int32 output; float
    inputs accumulate in f32 and default to the input dtype out (the MXU's
    native pairs, paper Section V).  A payload-codec B dtype (``int4`` /
    ``fp8e4m3``) passes through verbatim — the codec name IS the pricing
    and cache-key namespace — and defaults the accumulator to f32 (the
    per-tile dequant accumulates dequantized partials).
    """
    b_dtype = b_dtype or a_dtype
    b_codec = get_codec(b_dtype)
    out_dtype = out_dtype or ("int32" if jnp.dtype(a_dtype).kind == "i" else a_dtype)
    if acc_dtype is None:
        if b_codec is not None and b_codec.name != "int8":
            acc_dtype = "float32"
        else:
            acc_dtype = "int32" if jnp.dtype(a_dtype).kind == "i" else "float32"
    return (
        str(jnp.dtype(a_dtype)), plan_dtype(b_dtype),
        str(jnp.dtype(out_dtype)), str(jnp.dtype(acc_dtype)),
    )


def enumerate_block_lattice(
    m: int,
    n: int,
    k: int,
    a_dtype="float32",
    b_dtype=None,
    *,
    hw: HardwareSpec = DEFAULT_HW,
    max_block: int = 2048,
) -> Tuple[Tuple[int, ...], Tuple[int, ...], Tuple[int, ...]]:
    """The hardware-aligned candidate lattice (bm, bn, bk) the planner searches.

    Each axis is a power-of-two ladder from the granularity floor (sublane /
    lane / DMA-row-width alignment — the paper's P2 wide-load constraint) up
    to ``max_block``, plus an exact-fit candidate for small dims (the edge
    micro-kernel choice).  ``repro.tuning.microbench`` sweeps this same
    lattice so measured plans can never leave the space the kernel supports.
    """
    a_dtype, b_dtype, _, _ = _resolve_dtypes(a_dtype, b_dtype)
    lane = hw.lane
    min_bk = _min_span(a_dtype, hw.min_dma_row_bytes, lane)
    min_bn = _min_span(b_dtype, hw.min_dma_row_bytes, lane)
    sub_a = _sublane(hw, a_dtype)
    sub_b = _sublane(hw, b_dtype)

    def _cands(minimum: int, align: int, dim: int):
        out = []
        v = minimum
        while v <= min(max_block, _round_up(dim, align)):
            out.append(v)
            v *= 2
        exact = _round_up(dim, align)
        if exact <= max_block and exact not in out:
            out.append(exact)
        return sorted(set(out))

    bm_cands = _cands(max(sub_a, min(128, _round_up(m, sub_a))), sub_a, m)
    bm_cands = [c for c in bm_cands if c <= _round_up(m, sub_a)]
    bn_cands = [c for c in _cands(min_bn, lane, n) if c <= _round_up(n, lane)]
    bk_align = max(lane, sub_b)
    bk_cands = [c for c in _cands(min_bk, bk_align, k) if c <= _round_up(k, bk_align)]
    return tuple(bm_cands), tuple(bn_cands), tuple(bk_cands)


def modeled_traffic_bytes(
    m: int, n: int, k: int, bm: int, bn: int,
    a_bytes: float, b_bytes: float, c_bytes: float, beta: float = 0.0,
    extra_mn_inputs: int = 0, density: float = 1.0,
) -> int:
    """HBM traffic for a K-innermost revisiting grid (C resident in VMEM).

    A is re-read once per column-block of C; B once per row-block of C; C is
    written once (and read once iff beta != 0).  ``extra_mn_inputs`` counts
    additional (M, N)-shaped epilogue operands (gated-activation / residual
    fusions — core/gemm_spec.py), each read exactly once.  The per-element
    byte counts may be FRACTIONAL: sub-byte payload codecs (int4) price by
    bits-per-element, so a nibble-packed B stream costs 0.5 bytes/element.

    ``density`` < 1 prices a TILE-SPARSE B operand (repro.sparse): only the
    stored fraction of B tiles is ever DMA'd, and the A-side re-reads
    shrink the same way (the sparse walk skips the A block of a pruned
    (kk, j) tile too — grid steps, not just payload bytes, scale with
    density).  The epilogue/C terms do NOT scale: every output tile is
    still visited (anchor visits) and written exactly once.
    """
    n_col_blocks = math.ceil(n / bn)
    n_row_blocks = math.ceil(m / bm)
    c_factor = 2 if beta else 1
    return int(
        m * k * a_bytes * n_col_blocks * density
        + k * n * b_bytes * n_row_blocks * density
        + m * n * c_bytes * (c_factor + extra_mn_inputs)
    )


def vmem_working_set(
    bm: int, bn: int, bk: int,
    a_bytes: int, b_bytes: int, out_bytes: int, acc_bytes: int = 4,
    beta: float = 0.0, extra_mn_inputs: int = 0,
) -> int:
    """Paper eq (1), VMEM form.

    The paper reserves space for the *next* iteration's Bc and the C block on
    top of the current blocks (LRU anti-eviction).  The TPU analogue is the
    Pallas pipeline's double buffering of the streamed inputs, plus the
    resident accumulator and the output staging block.  Each extra
    (M, N)-shaped epilogue operand (gated/residual fusions) streams one more
    double-buffered (bm, bn) block.
    """
    dbuf = 2  # double-buffered HBM->VMEM pipeline
    ws = dbuf * (bm * bk * a_bytes + bk * bn * b_bytes)
    ws += bm * bn * acc_bytes          # resident accumulator (the "ZA tiles")
    ws += bm * bn * out_bytes          # output staging
    if beta:
        ws += dbuf * bm * bn * out_bytes   # streamed C input blocks
    ws += extra_mn_inputs * dbuf * bm * bn * out_bytes  # epilogue operands
    return int(ws)


def plan_gemm(
    m: int,
    n: int,
    k: int,
    a_dtype="float32",
    b_dtype=None,
    out_dtype=None,
    acc_dtype=None,
    *,
    beta: float = 0.0,
    extra_mn_inputs: int = 0,
    density: float = 1.0,
    hw: HardwareSpec = DEFAULT_HW,
    vmem_budget_frac: float = 0.75,
    max_block: int = 2048,
) -> GemmPlan:
    """Pick (bm, bn, bk) for an M x N x K GEMM.

    Mirrors the paper's flow: fix the register-level micro tile from the ISA
    (here the MXU's 128), derive the reduction block from the granularity
    constraint (paper: TLB eq (2); here: DMA row width), then maximize CMR
    subject to the capacity constraint (paper: 8 MB L2; here: VMEM budget).

    ``density`` < 1 prices a tile-sparse B operand (repro.sparse): skipped
    tiles cost neither HBM bytes (A and B streams scale with density) nor
    MACs (FLOPs scale the same way), so the CMR objective — and therefore
    the chosen blocks — reflects the sparse launch the plan will serve.
    """
    a_dtype, b_dtype, out_dtype, acc_dtype = _resolve_dtypes(
        a_dtype, b_dtype, out_dtype, acc_dtype
    )
    ab = _dtype_bytes(a_dtype)
    bb = _dtype_bytes(b_dtype)
    ob = _dtype_bytes(out_dtype)
    accb = _dtype_bytes(acc_dtype)

    budget = int(hw.vmem_bytes * vmem_budget_frac)
    lane = hw.lane
    sub_a = _sublane(hw, a_dtype)   # A/acc second-minor granularity
    sub_b = _sublane(hw, b_dtype)   # B second-minor granularity (bk)
    bk_align = max(lane, sub_b)

    # Granularity floors (paper P2: four-Z-register loads) are baked into the
    # lattice: minor-dim spans cover >= min_dma_row_bytes of contiguous data.
    bm_cands, bn_cands, bk_cands = enumerate_block_lattice(
        m, n, k, a_dtype, b_dtype, hw=hw, max_block=max_block
    )

    best = None
    for bm in bm_cands:
        for bn in bn_cands:
            for bk in bk_cands:
                ws = vmem_working_set(bm, bn, bk, ab, bb, ob, accb, beta,
                                      extra_mn_inputs)
                if ws > budget:
                    continue
                traffic = modeled_traffic_bytes(m, n, k, bm, bn, ab, bb, ob,
                                                beta, extra_mn_inputs,
                                                density)
                flops = int(2 * m * n * k * density)
                cmr = flops / max(1, traffic)
                # Secondary objectives: fewer grid steps, squarer C block.
                grid_steps = (
                    math.ceil(m / bm) * math.ceil(n / bn) * math.ceil(k / bk)
                )
                key = (cmr, -grid_steps, min(bm, bn))
                if best is None or key > best[0]:
                    best = (key, (bm, bn, bk, ws, traffic, cmr))
    if best is None:
        # Degenerate fallback: smallest aligned blocks.
        bm, bn, bk = sub_a, lane, bk_align
    else:
        bm, bn, bk = best[1][:3]
    return plan_with_blocks(
        m, n, k, bm, bn, bk, a_dtype, b_dtype, out_dtype, acc_dtype,
        beta=beta, extra_mn_inputs=extra_mn_inputs, density=density, hw=hw,
    )


def plan_with_blocks(
    m: int,
    n: int,
    k: int,
    bm: int,
    bn: int,
    bk: int,
    a_dtype="float32",
    b_dtype=None,
    out_dtype=None,
    acc_dtype=None,
    *,
    beta: float = 0.0,
    extra_mn_inputs: int = 0,
    density: float = 1.0,
    hw: HardwareSpec = DEFAULT_HW,
    notes: str = "",
) -> GemmPlan:
    """Price one lattice point: a :class:`GemmPlan` for *forced* (bm, bn, bk).

    Blocks are clamped to the problem's aligned extent and all derived model
    terms (grid, VMEM working set, HBM traffic, CMR, K-edge predication) are
    recomputed, so a tuned plan carries the same metadata as an analytic one.
    The autotuner (repro.tuning) is the main caller.
    """
    a_dtype, b_dtype, out_dtype, acc_dtype = _resolve_dtypes(
        a_dtype, b_dtype, out_dtype, acc_dtype
    )
    ab = _dtype_bytes(a_dtype)
    bb = _dtype_bytes(b_dtype)
    ob = _dtype_bytes(out_dtype)
    accb = _dtype_bytes(acc_dtype)
    sub_a = _sublane(hw, a_dtype)
    bk_align = max(hw.lane, _sublane(hw, b_dtype))

    bm = min(bm, _round_up(m, sub_a))
    bn = min(bn, _round_up(n, hw.lane))
    bk = min(bk, _round_up(k, bk_align))
    ws = vmem_working_set(bm, bn, bk, ab, bb, ob, accb, beta,
                          extra_mn_inputs)
    traffic = modeled_traffic_bytes(m, n, k, bm, bn, ab, bb, ob, beta,
                                    extra_mn_inputs, density)
    flops = int(2 * m * n * k * density)
    grid = (math.ceil(m / bm), math.ceil(n / bn), math.ceil(k / bk))
    auto_notes = [notes] if notes else []
    if density < 1.0:
        auto_notes.append(f"density={density:.2f}")
    if m % bm or n % bn:
        auto_notes.append("edge-mn")
    k_rem = k % bk
    if k_rem:
        auto_notes.append("edge-k(predicated)")
    return GemmPlan(
        m=m, n=n, k=k, bm=bm, bn=bn, bk=bk,
        a_dtype=a_dtype, b_dtype=b_dtype,
        out_dtype=out_dtype, acc_dtype=acc_dtype,
        grid=grid, vmem_bytes=ws, hbm_bytes=traffic, flops=flops,
        cmr=flops / max(1, traffic), k_rem=k_rem,
        notes=" ".join(auto_notes),
    )


def grouped_plan_from_2d(plan: GemmPlan, g: int) -> GemmPlan:
    """Lift a 2-D plan to a G-group batched one (group = leading grid axis).

    Groups are independent problems streamed back-to-back, so there is no
    cross-group reuse to model: per-group traffic and FLOPs simply scale by
    G (CMR is invariant), and the VMEM working set is unchanged — each grid
    step still stages one (bm, bk)/(bk, bn) input pair and one (bm, bn)
    accumulator, now for whichever group the leading grid index names.
    """
    if g < 1:
        raise ValueError(f"group count must be >= 1, got {g}")
    if g == 1:
        return plan
    notes = " ".join(x for x in (plan.notes, f"grouped(g={g})") if x)
    return dataclasses.replace(
        plan, g=g, flops=plan.flops * g, hbm_bytes=plan.hbm_bytes * g,
        notes=notes,
    )


def plan_grouped_gemm(
    g: int,
    m: int,
    n: int,
    k: int,
    a_dtype="float32",
    b_dtype=None,
    out_dtype=None,
    acc_dtype=None,
    *,
    beta: float = 0.0,
    hw: HardwareSpec = DEFAULT_HW,
    vmem_budget_frac: float = 0.75,
    max_block: int = 2048,
) -> GemmPlan:
    """Block plan for a grouped GEMM: G independent M x N x K problems.

    The per-group blocking solve is exactly the 2-D one — the group axis
    adds grid steps, not working set — so the analytic optimum is the 2-D
    optimum lifted by :func:`grouped_plan_from_2d`.  Consumed by
    ``kernels/mpgemm.py::mpgemm_grouped_pallas`` (grid ``(G, M/bm, N/bn,
    K/bk)``) and priced by the MoE-workload benchmarks.
    """
    base = plan_gemm(
        m, n, k, a_dtype, b_dtype, out_dtype, acc_dtype,
        beta=beta, hw=hw, vmem_budget_frac=vmem_budget_frac,
        max_block=max_block,
    )
    return grouped_plan_from_2d(base, g)


def plan_to_dict(plan: GemmPlan) -> dict:
    """JSON-safe dict form of a plan (tuning/plan_cache.py wire format)."""
    d = dataclasses.asdict(plan)
    d["grid"] = list(plan.grid)
    return d


def plan_from_dict(d: dict) -> GemmPlan:
    """Inverse of :func:`plan_to_dict`."""
    d = dict(d)
    d["grid"] = tuple(d["grid"])
    return GemmPlan(**d)


def naive_plan(m: int, n: int, k: int, a_dtype="float32", **kw) -> GemmPlan:
    """The 'three-level loop, fixed tile' baseline the paper ablates against.

    Fixed 256^3 blocks regardless of shape or dtype — the analogue of the
    baselines' fixed micro-tile + single-matrix packing.  Used by
    benchmarks/bench_breakdown.py.
    """
    plan = plan_gemm(m, n, k, a_dtype, **kw)
    bm = min(256, _round_up(m, 8))
    bn = min(256, _round_up(n, 128))
    bk = min(256, _round_up(k, 128))
    ab = _dtype_bytes(plan.a_dtype)
    bb = _dtype_bytes(plan.b_dtype)
    ob = _dtype_bytes(plan.out_dtype)
    traffic = modeled_traffic_bytes(m, n, k, bm, bn, ab, bb, ob)
    return dataclasses.replace(
        plan, bm=bm, bn=bn, bk=bk,
        grid=(math.ceil(m / bm), math.ceil(n / bn), math.ceil(k / bk)),
        vmem_bytes=vmem_working_set(bm, bn, bk, ab, bb, ob),
        hbm_bytes=traffic, cmr=2 * m * n * k / max(1, traffic),
        k_rem=k % bk, notes="naive-256^3",
    )
