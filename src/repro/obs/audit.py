"""Reusable jaxpr launch auditor — the one implementation of the trace
gates that PRs 3–9 each hand-rolled inside a bench module.

Everything here operates on a traced jaxpr (``trace(fn, *args)`` or
``jax.make_jaxpr(...)(...).jaxpr``) and returns exact, timing-free
facts about the launch schedule:

* ``count_pallas``          — Pallas launches anywhere in the program;
* ``pallas_grids`` /
  ``first_pallas_grid``     — the grid of each ``pallas_call`` (the
                              sparse/paged gates read the innermost axis:
                              stored-tile schedule length, block-table
                              width);
* ``primitive_counts``      — XLA-level primitive histogram, *skipping*
                              pallas kernel bodies (in-kernel ops are
                              fused — that is the point);
* ``weight_sized_intermediates`` — count and bytes of weight-sized
                              outputs of a primitive set (per-call prep
                              passes, dequant materializations);
* ``op_sequence`` /
  ``schedule_counts``       — the ordered GEMM/collective schedule and
                              the ring-interleave summary the
                              distributed gate asserts on.

The set constants (``PREP_PRIMS``, ``DEQUANT_PRIMS``) moved here from
``bench_packing`` / ``bench_quant`` so tests and future gates import one
definition.  This module imports jax lazily-at-call so ``repro.obs``
stays importable without it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "DEQUANT_PRIMS",
    "PREP_PRIMS",
    "SCHEDULE_OPS",
    "LaunchAudit",
    "audit",
    "count_pallas",
    "first_pallas_grid",
    "op_sequence",
    "pallas_grids",
    "prep_bytes",
    "primitive_counts",
    "schedule_counts",
    "trace",
    "weight_sized_intermediates",
]

#: Layout/prep primitives whose weight-sized outputs are the per-call
#: operand preparation that ahead-of-time packing eliminates (casts,
#: transposes, per-tensor dynamic quantization chains).
PREP_PRIMS = frozenset({
    "transpose", "convert_element_type", "pad", "round", "clamp", "abs",
    "mul", "div", "max", "min", "reduce_max", "integer_pow", "sign",
    "optimization_barrier", "stop_gradient",
})

#: Primitives a separate dequantization pass materializes through; a
#: weight-sized output of one of these OUTSIDE a kernel body means the
#: nibble/scale decode is not riding the accumulation loop.
DEQUANT_PRIMS = frozenset({"convert_element_type", "mul", "div"})

#: The ops that make up a sharded-GEMM schedule (order-preserved by
#: ``op_sequence``; ``schedule_counts`` summarizes interleaving).
SCHEDULE_OPS = ("dot_general", "pallas_call", "ppermute", "psum",
                "all_to_all")


def trace(fn, *args, **kwargs):
    """The jaxpr of ``fn(*args, **kwargs)`` (ShapeDtypeStructs welcome)."""
    import jax
    return jax.make_jaxpr(fn)(*args, **kwargs).jaxpr


def _sub_jaxprs(eqn):
    import jax
    return jax.core.jaxprs_in_params(eqn.params)


def _is_pallas(eqn) -> bool:
    return "pallas" in eqn.primitive.name


def count_pallas(jaxpr) -> int:
    """Pallas launches anywhere in a jaxpr (recursing into sub-jaxprs)."""
    n = 0
    for eqn in jaxpr.eqns:
        if _is_pallas(eqn):
            n += 1
        for sub in _sub_jaxprs(eqn):
            n += count_pallas(sub)
    return n


def pallas_grids(jaxpr) -> List[tuple]:
    """The grid of every ``pallas_call``, in program order."""
    grids: List[tuple] = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            grids.append(tuple(eqn.params["grid_mapping"].grid))
        for sub in _sub_jaxprs(eqn):
            grids.extend(pallas_grids(sub))
    return grids


def first_pallas_grid(jaxpr) -> tuple:
    """Grid of the first ``pallas_call``; raises if the fn never launches
    a kernel (the gates treat that as a broken dispatch, not a zero)."""
    grids = pallas_grids(jaxpr)
    if not grids:
        raise ValueError("traced fn contains no pallas_call")
    return grids[0]


def primitive_counts(jaxpr, counts: Optional[Dict[str, int]] = None,
                     *, skip_pallas_bodies: bool = True) -> Dict[str, int]:
    """Primitive-name histogram.  By default pallas kernel bodies are
    skipped (their internal ops are fused in-kernel), matching the
    epilogue gate's notion of "stand-alone" XLA ops."""
    if counts is None:
        counts = {}
    for eqn in jaxpr.eqns:
        counts[eqn.primitive.name] = counts.get(eqn.primitive.name, 0) + 1
        if skip_pallas_bodies and eqn.primitive.name == "pallas_call":
            continue
        for sub in _sub_jaxprs(eqn):
            primitive_counts(sub, counts,
                             skip_pallas_bodies=skip_pallas_bodies)
    return counts


def weight_sized_intermediates(jaxpr, weight_elems: int, *,
                               prims: frozenset = PREP_PRIMS,
                               skip_pallas_bodies: bool = False,
                               ) -> Tuple[int, int]:
    """(count, bytes) of weight-sized outputs produced by ``prims``.

    With the default ``prims=PREP_PRIMS`` and recursion into kernel
    bodies this is the packing gate's per-call prep traffic; with
    ``prims=DEQUANT_PRIMS, skip_pallas_bodies=True`` it is the quant
    gate's dequant-materialization count.  Size-based isolation: a
    weight-sized transpose/convert/scale output IS the pass being
    audited; activation-side ops have different extents (callers pick a
    trace-time M distinct from N and K).
    """
    count = 0
    total = 0
    for eqn in jaxpr.eqns:
        if not (skip_pallas_bodies and _is_pallas(eqn)):
            for sub in _sub_jaxprs(eqn):
                c, b = weight_sized_intermediates(
                    sub, weight_elems, prims=prims,
                    skip_pallas_bodies=skip_pallas_bodies)
                count += c
                total += b
        if eqn.primitive.name not in prims:
            continue
        for var in eqn.outvars:
            aval = var.aval
            if getattr(aval, "size", 0) == weight_elems:
                count += 1
                total += aval.size * aval.dtype.itemsize
    return count, total


def prep_bytes(fn, *args, weight_elems: int) -> int:
    """Bytes of weight-sized prep intermediates in the traced fn."""
    return weight_sized_intermediates(trace(fn, *args), weight_elems)[1]


def op_sequence(jaxpr, names: Sequence[str] = SCHEDULE_OPS) -> List[str]:
    """Ordered occurrences of ``names`` (program order, recursing into
    every sub-jaxpr) — the raw material of the interleaving gate."""
    nameset = frozenset(names)
    out: List[str] = []

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name in nameset:
                out.append(eqn.primitive.name)
            for sub in _sub_jaxprs(eqn):
                walk(sub)

    walk(jaxpr)
    return out


def schedule_counts(jaxpr) -> Dict[str, int]:
    """The distributed gate's schedule summary: GEMM count, collective
    counts, and whether every ppermute is separated from the next by a
    chunk GEMM (``interleaved``)."""
    ops = op_sequence(jaxpr)
    seq = "".join("P" if o == "ppermute" else "D"
                  for o in ops if o != "psum" and o != "all_to_all")
    return {"dots": sum(1 for o in ops
                        if o in ("dot_general", "pallas_call")),
            "ppermutes": ops.count("ppermute"),
            "psums": ops.count("psum"),
            "all_to_alls": ops.count("all_to_all"),
            "interleaved": int("PP" not in seq and "P" in seq)}


@dataclasses.dataclass(frozen=True)
class LaunchAudit:
    """One traced fn's launch facts, bundled for tests and reports."""

    pallas_calls: int
    grids: Tuple[tuple, ...]
    primitives: Dict[str, int]       # outside pallas bodies
    collectives: Dict[str, int]

    @property
    def single_launch(self) -> bool:
        return self.pallas_calls == 1


def audit(fn, *args, **kwargs) -> LaunchAudit:
    """Trace ``fn`` and collect the standard launch facts."""
    jaxpr = trace(fn, *args, **kwargs)
    prims = primitive_counts(jaxpr)
    return LaunchAudit(
        pallas_calls=count_pallas(jaxpr),
        grids=tuple(pallas_grids(jaxpr)),
        primitives=prims,
        collectives={name: prims.get(name, 0)
                     for name in ("ppermute", "psum", "all_to_all",
                                  "all_gather", "reduce_scatter")},
    )
