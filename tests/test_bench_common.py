"""Contracts of benchmarks/common.py: the wall-timer, the Table III
workload set, and the recorder plumbing every bench module calls."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common

# Paper Table III, verbatim: 18 DeepSeek shapes (IDs 1-18, M in
# {64, 128, 4096}) + 6 LLaMA shapes (IDs 19-24).  The benchmarks, the
# committed baselines, and EXPERIMENTS.md all cite these 24 rows — a silent
# edit here would invalidate every downstream number, so the set is pinned
# exactly.
TABLE_III = [
    (1, 64, 2112, 7168), (2, 64, 24576, 1536), (3, 64, 32768, 512),
    (4, 64, 7168, 16384), (5, 64, 4096, 7168), (6, 64, 7168, 2048),
    (7, 128, 2112, 7168), (8, 128, 24576, 1536), (9, 128, 32768, 512),
    (10, 128, 7168, 16384), (11, 128, 4096, 7168), (12, 128, 7168, 2048),
    (13, 4096, 2112, 7168), (14, 4096, 24576, 1536), (15, 4096, 32768, 512),
    (16, 4096, 7168, 16384), (17, 4096, 4096, 7168), (18, 4096, 7168, 2048),
    (19, 4096, 256, 4096), (20, 11008, 256, 4096), (21, 4096, 256, 11008),
    (22, 5120, 256, 5120), (23, 13824, 256, 5120), (24, 5120, 256, 13824),
]


class TestPaperWorkloads:
    def test_exactly_table_iii(self):
        assert common.PAPER_WORKLOADS == TABLE_III

    def test_ids_are_1_to_24(self):
        assert [w[0] for w in common.PAPER_WORKLOADS] == list(range(1, 25))

    def test_moe_grouped_shapes_positive(self):
        for name, g, m, n, k in common.MOE_GROUPED_WORKLOADS:
            assert g > 1 and m > 0 and n > 0 and k > 0, name


class TestWallTimeUs:
    def test_warmup_and_iters_contract(self):
        calls = []

        def fn(x):
            calls.append(x)
            return x

        us = common.wall_time_us(fn, 1.0, iters=3, warmup=2)
        # warmup runs are excluded from timing but still executed
        assert len(calls) == 2 + 3
        assert us >= 0.0

    def test_returns_best_of_iters_in_us(self):
        import time
        t = iter([0.0, 1.0,      # iter 1: 1.0 s
                  1.0, 1.001,    # iter 2: 1 ms  <- best
                  1.001, 1.101])  # iter 3: 100 ms
        real = time.perf_counter
        time.perf_counter = lambda: next(t)
        try:
            us = common.wall_time_us(lambda: 0, iters=3, warmup=0)
        finally:
            time.perf_counter = real
        assert us == pytest.approx(1000.0)  # best iter, microseconds

    def test_zero_warmup_times_first_call(self):
        calls = []
        common.wall_time_us(lambda: calls.append(1), iters=1, warmup=0)
        assert len(calls) == 1


class TestRecorderPlumbing:
    def test_record_noops_without_recorder(self):
        assert common.get_recorder() is None
        # must not raise, must not require repro.perf to be imported
        common.record("x", "gemm", metrics={"a_us": 1.0})
        common.record_plan("y", "gemm", None)

    def test_set_recorder_routes_records(self):
        from repro.perf.trajectory import Recorder
        rec = Recorder()
        old = common.set_recorder(rec)
        try:
            common.record("w", "gemm", workload={"m": 1},
                          metrics={"a_us": 2.0}, noisy={"wall_us": 3.0})
            from repro.core.blocking import plan_gemm
            common.record_plan("p", "sparse", plan_gemm(64, 256, 512))
        finally:
            common.set_recorder(old)
        assert common.get_recorder() is old
        assert len(rec) == 2
        got = rec.records("gemm")[0]
        assert got.metrics == {"a_us": 2.0}
        assert got.noisy == {"wall_us": 3.0}
        assert rec.records("sparse")[0].metrics["flops"] == 2 * 64 * 256 * 512

    def test_invalid_record_raises_with_recorder(self):
        from repro.perf.trajectory import Recorder
        old = common.set_recorder(Recorder())
        try:
            with pytest.raises(ValueError):
                common.record("bad", "gemm", metrics={"x": "nan-string"})
        finally:
            common.set_recorder(old)
