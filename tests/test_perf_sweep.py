"""Offline plan-cache sweep (repro.perf.sweep): enumeration fidelity and
the acceptance gate — every enumerated (config × policy × layout ×
epilogue) combo must leave a PlanCache hit."""
import pytest

from repro.configs import base as cb
from repro.core.blocking import plan_gemm
from repro.perf.sweep import (
    LAYOUTS, PACK_M_HINT, SERVE_POLICIES, enumerate_gemm_instances,
    enumerate_shipped_combos, verify_warm, warm_plan_cache,
)
from repro.tuning.plan_cache import PlanCache, make_key


@pytest.fixture
def cache(tmp_path):
    return PlanCache(str(tmp_path / "plans.json"))


# One dense arch, one MoE arch, one recurrent arch — covers every
# instance-derivation branch without sweeping all ten configs.
ARCH_SAMPLE = ("h2o-danube3-4b", "granite-moe-1b-a400m", "rwkv6-1.6b")


class TestEnumeration:
    def test_dense_arch_instances(self):
        cfg = cb.get("h2o-danube3-4b", smoke=True)
        roles = {i.role for i in enumerate_gemm_instances(cfg, m_tokens=32)}
        assert {"attn_q", "attn_kv", "attn_out", "mlp_up", "mlp_gate",
                "mlp_down", "logits"} <= roles
        assert not any(r.startswith("moe") for r in roles)

    def test_moe_arch_gets_grouped_experts(self):
        cfg = cb.get("granite-moe-1b-a400m", smoke=True)
        insts = {i.role: i for i in enumerate_gemm_instances(cfg,
                                                            m_tokens=32)}
        assert insts["moe_up"].g == cfg.n_experts
        assert insts["moe_router"].force_policy == "fp32"
        assert insts["moe_gate"].epilogue_kind == "gated"
        assert insts["moe_gate"].activation == "silu"
        # moe_mlp keeps f32 activations between expert GEMMs and combine
        assert insts["moe_up"].force_out_dtype == "float32"
        # capacity rule: ceil-ish round of 1.25 * topk * T / E
        expect = max(1, int(round(
            1.25 * cfg.experts_per_token * 32 / cfg.n_experts)))
        assert insts["moe_up"].m == expect

    def test_recurrent_arch_instances(self):
        cfg = cb.get("rwkv6-1.6b", smoke=True)
        roles = {i.role for i in enumerate_gemm_instances(cfg, m_tokens=32)}
        assert "rec_mix" in roles and "attn_q" not in roles

    def test_swiglu_epilogues(self):
        cfg = cb.get("h2o-danube3-4b", smoke=True)
        insts = {i.role: i for i in enumerate_gemm_instances(cfg,
                                                            m_tokens=32)}
        assert insts["mlp_gate"].epilogue().tag == "gated-silu"
        assert insts["mlp_down"].epilogue().tag == "residual"
        assert insts["mlp_up"].epilogue() is None

    def test_combos_deduplicated(self):
        combos = enumerate_shipped_combos(ARCH_SAMPLE, m_tokens=(32,),
                                          smoke=True)
        keys = [c.key for c in combos]
        assert len(keys) == len(set(keys))
        assert combos, "no combos enumerated"

    def test_combo_axes_covered(self):
        combos = enumerate_shipped_combos(ARCH_SAMPLE, m_tokens=(32,),
                                          smoke=True)
        # bf16_serve keys collide with bf16 (same launch dtypes) and are
        # deduplicated away — only distinctly-keyed policies survive.
        assert {c.policy for c in combos} == {"bf16", "int8"}
        assert {c.layout for c in combos} == set(LAYOUTS)
        # fused-epilogue namespaces present among the enumerated keys
        assert any("|ep=gated-silu" in c.key for c in combos)
        assert any("|ep=residual" in c.key for c in combos)
        assert any("|lay=packB" in c.key for c in combos)
        assert any(c.key.startswith("g") for c in combos)   # grouped MoE

    def test_int8_policy_quantizes_operand_dtypes(self):
        combos = enumerate_shipped_combos(("h2o-danube3-4b",),
                                          policies=("int8",),
                                          layouts=("dense",),
                                          m_tokens=(32,), smoke=True)
        assert all("|a=int8|b=int8|" in c.key for c in combos
                   if c.instance.force_policy is None)

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError, match="unknown policy"):
            enumerate_shipped_combos(("h2o-danube3-4b",),
                                     policies=("fp64",), smoke=True)


class TestWarm:
    def test_every_combo_hits_after_sweep(self, cache):
        """THE acceptance gate: repro.perf.sweep leaves a PlanCache hit
        for every shipped combination it enumerates."""
        combos = enumerate_shipped_combos(ARCH_SAMPLE,
                                          m_tokens=(32, 4096), smoke=True)
        result = warm_plan_cache(combos, cache, mode="modeled")
        assert result.warmed == len(combos)
        assert verify_warm(combos, cache) == []

    def test_sweep_idempotent(self, cache):
        combos = enumerate_shipped_combos(("h2o-danube3-4b",),
                                          m_tokens=(32,), smoke=True)
        first = warm_plan_cache(combos, cache, mode="modeled")
        second = warm_plan_cache(combos, cache, mode="modeled")
        assert first.warmed == len(combos)
        assert second.warmed == 0 and second.skipped == len(combos)

    def test_packed_plan_blocks_pinned_to_layout(self, cache):
        """A swept packed plan must carry the payload layout's (bn, bk) —
        kernels/mpgemm.py::_layout_plan DISCARDS mismatched plans."""
        combos = [c for c in enumerate_shipped_combos(
            ("h2o-danube3-4b",), policies=("bf16",), m_tokens=(32,),
            smoke=True) if c.layout == "packed"]
        warm_plan_cache(combos, cache, mode="modeled")
        for c in combos:
            plan = cache.get(c.key)
            layout_plan = plan_gemm(PACK_M_HINT, c.instance.n, c.instance.k,
                                    "bfloat16", "bfloat16")
            assert (plan.bn, plan.bk) == (layout_plan.bn, layout_plan.bk), \
                c.key

    def test_launch_resolver_accepts_swept_packed_plan(self, cache):
        """End to end: pack a weight the way load-time packing does, and
        the launch-side resolver must return the SWEPT plan, not fall back
        to the analytic solve."""
        import jax.numpy as jnp
        from repro.kernels.mpgemm import _layout_plan
        from repro.packing import pack_operand
        combos = [c for c in enumerate_shipped_combos(
            ("h2o-danube3-4b",), policies=("bf16",), m_tokens=(32,),
            smoke=True)
            if c.layout == "packed" and c.instance.g == 1
            and c.instance.epilogue() is None][:1]
        assert combos
        c = combos[0]
        warm_plan_cache(combos, cache, mode="modeled")
        from repro.tuning import plan_cache as pc
        old = pc.set_plan_cache(cache)
        try:
            inst = c.instance
            lp = plan_gemm(PACK_M_HINT, inst.n, inst.k, "bfloat16",
                           "bfloat16")
            packed = pack_operand(jnp.zeros((inst.k, inst.n), jnp.float32),
                                  (lp.bk, lp.bn), dtype="bfloat16",
                                  backend="xla")
            got = _layout_plan(inst.m, inst.k, inst.n, packed.layout,
                               "bfloat16", "bfloat16", False, 0.0,
                               sparse=False, g=1, epilogue_tag="")
            want = cache.get(c.key)
            assert (got.bm, got.bn, got.bk) == (want.bm, want.bn, want.bk)
        finally:
            pc.set_plan_cache(old)

    def test_dense_keys_match_tuner_keys(self, cache):
        """Enumerated keys must be byte-identical to what the tuner
        persists (warm_plan_cache raises on drift; this pins one example)."""
        combos = [c for c in enumerate_shipped_combos(
            ("h2o-danube3-4b",), policies=("bf16",), layouts=("dense",),
            m_tokens=(32,), smoke=True) if c.instance.role == "mlp_gate"]
        assert combos
        c = combos[0]
        inst = c.instance
        assert c.key == make_key(
            inst.m, inst.n, inst.k, "bfloat16", "bfloat16", "bfloat16",
            epilogue="gated-silu")
        warm_plan_cache(combos, cache, mode="modeled")
        assert cache.get(c.key) is not None


def test_cli_smoke(tmp_path, capsys):
    from repro.perf.sweep import main
    rc = main(["--out", str(tmp_path / "plans.json"),
               "--archs", "h2o-danube3-4b", "--m-tokens", "32",
               "--mode", "modeled", "--smoke"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "every enumerated combo has a PlanCache hit" in out
