"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.gemm_spec import apply_epilogue, resolve_epilogue


def mpgemm_ref(
    a,
    b,
    c=None,
    *,
    trans_a: bool = False,
    trans_b: bool = False,
    alpha: float = 1.0,
    beta: float = 0.0,
    bias=None,
    scale=None,
    activation: Optional[str] = None,
    gate=None,
    residual=None,
    out_dtype=None,
    acc_dtype=None,
):
    """Oracle for ``kernels.mpgemm.mpgemm_pallas`` — and, with rank-3
    operands (leading group dim), for ``mpgemm_grouped_pallas``.

    The epilogue semantics come from the SAME implementation the kernel
    body uses (``core/gemm_spec.py::apply_epilogue``), so the oracle and
    the kernel cannot drift; only the matmul itself is re-derived here.
    """
    if acc_dtype is None:
        acc_dtype = jnp.int32 if jnp.dtype(a.dtype).kind == "i" else jnp.float32
    if out_dtype is None:
        out_dtype = jnp.int32 if jnp.dtype(a.dtype).kind == "i" else a.dtype
    lhs = jnp.swapaxes(a, -1, -2) if trans_a else a
    rhs = jnp.swapaxes(b, -1, -2) if trans_b else b
    acc = jnp.matmul(lhs, rhs, preferred_element_type=acc_dtype)
    if bias is not None:
        n = acc.shape[-1]
        if acc.ndim == 3:  # grouped: (G, N) per-group or (N,) shared
            bias = jnp.broadcast_to(
                bias.reshape((1, -1) if bias.ndim == 1 else
                             (bias.shape[0], -1))[:, None, :],
                (acc.shape[0], 1, n))
        else:
            bias = bias.reshape(1, -1)
    ep, extras = resolve_epilogue({"gate": gate, "residual": residual},
                                  activation=activation, alpha=alpha,
                                  beta=beta)
    acc = apply_epilogue(ep, acc, bias=bias, scale=scale, c=c, extras=extras)
    return acc.astype(out_dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None, bias=None):
    """Oracle for kernels.flash_attention (q,k,v: [T, H] per head, or batched)."""
    sm_scale = scale if scale is not None else 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) * sm_scale
    tq, tk = q.shape[-2], k.shape[-2]
    qi = jnp.arange(tq)[:, None] + (tk - tq)  # right-aligned for decode
    ki = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    if bias is not None:
        logits = logits + bias
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", probs, v.astype(probs.dtype)).astype(q.dtype)
